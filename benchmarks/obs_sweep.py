"""Observability sweep: one merged timeline, latency quantiles, calibration.

Drives BOTH engines over a warm tiered cache with a REMOTE cold tier
(2 simulated hosts) under one :class:`repro.obs.Telemetry`, then closes
the measurement loop three ways:

  * TRACE    — exports the merged Chrome trace-event / Perfetto JSON
    (engine, pipeline, request, cache, and comm lanes on one
    ``perf_counter`` clock) and asserts the golden schema plus presence
    of spans from both engines and at least one runtime-timestamped
    ``fetch_rows`` collective;
  * LATENCY  — prints each engine's enqueue->score p50/p95/p99 from the
    ``<engine>.request_latency_s`` histograms;
  * CALIBRATE — fits ``perf_model.Hardware`` serving-stage constants
    (``gather_overhead_s`` / ``host_Bps`` / fetch-transport α–β) from
    the TRAIN window's measured spans and asserts the fitted model
    predicts the HELD-OUT window's stage times with lower relative
    error than the hand-set ``H100_DGX`` / ``TPU_V5E`` constants.
    (On this CPU host the hand-set accelerator constants underpredict
    wall-clock by orders of magnitude — the point of the assertion is
    that the fit actually tracks the measured platform.)

Telemetry cost is bounded too: per-op record costs are microbenchmarked
and multiplied by the actual event/observation counts; the projected
overhead must stay under 2% of the serving wall-clock.

Artifacts: ``--trace`` (Chrome JSON, load at ui.perfetto.dev or
chrome://tracing), ``--metrics`` (versioned ``write_snapshot`` JSON with
the calibration numbers — CI's ``BENCH_obs.json``), ``--csv`` (the
calibration error table as a :class:`repro.obs.SweepReport`).
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import os
import time

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=2")

import numpy as np          # noqa: E402
import jax                  # noqa: E402

from repro.configs import dlrm as dlrm_cfg                      # noqa: E402
from repro.core.cache_config import CacheConfig                 # noqa: E402
from repro.core.perf_model import (                             # noqa: E402
    H100_DGX, TPU_V5E, calibrate, stage_time_error)
from repro.models import dlrm as dlrm_mod                       # noqa: E402
from repro.obs import (                                         # noqa: E402
    Histogram, SweepReport, Telemetry, Tracer, validate_chrome_trace,
    write_snapshot)
from repro.obs.bench import (                                   # noqa: E402
    make_bench_record, make_metric, write_bench)
from repro.serving.engine import CTRRequest, make_dlrm_engine   # noqa: E402

SHAPE = dict(tables=4, rows=1 << 12, dim=32, pooling=8, cache=256,
             zipf=1.05, hosts=2)
# window sizes (requests per flush): varied so the h2d / fetch_remote
# least-squares design matrices span a real byte range — identical
# windows would make the affine fit rank-1
FULL = dict(train=(4, 8, 16, 32) * 3, hold=(6, 12, 24) * 2, piped=4)
SMOKE = dict(train=(4, 8, 16, 32), hold=(6, 24), piped=2)


def _config(shape: dict, *, depth: int = 1) -> dlrm_cfg.DLRMConfig:
    return dlrm_cfg.DLRMConfig(
        num_sparse_features=shape["tables"],
        rows_per_table=shape["rows"],
        embedding_dim=shape["dim"],
        pooling=shape["pooling"],
        num_dense_features=4,
        bottom_mlp=(64, shape["dim"]),
        top_mlp=(64, 1),
        kernel_mode="reference",          # CPU-tractable, same both engines
        cache=CacheConfig(rows=shape["cache"], policy="lru",
                          cold_tier="remote", remote_hosts=shape["hosts"],
                          pipeline_depth=depth),
    )


def _requests(cfg, n, rng, rid0=0, zipf=1.05):
    T, L, F = (cfg.num_sparse_features, cfg.pooling,
               cfg.num_dense_features)
    R = cfg.rows_per_table
    out = []
    for rid in range(rid0, rid0 + n):
        idx = np.minimum(rng.zipf(zipf, size=(T, L)) - 1, R - 1)
        out.append(CTRRequest(
            rid=rid, dense=rng.standard_normal(F).astype(np.float32),
            indices=idx.astype(np.int32),
            lengths=np.full(T, L, np.int32)))
    return out


def _serve(engine, cfg, windows, rng, rid0, zipf) -> float:
    """One flush per window size; returns (serving seconds, next rid)."""
    t0 = time.perf_counter()
    for n in windows:
        for r in _requests(cfg, n, rng, rid0=rid0, zipf=zipf):
            engine.submit(r)
        rid0 += n
        engine.run_to_completion()
    return time.perf_counter() - t0, rid0


def _prewarm_buckets(engine) -> None:
    """Compile the cold-tier fetch and donated pool-scatter programs for
    every power-of-two request bucket (``_pad_pow2``) a flush can hit —
    one-off jit compiles would otherwise land INSIDE measured prefetch
    spans and poison the calibration fit with multi-ms outliers."""
    cache = engine.cache
    bags = cache.buffers if hasattr(cache, "buffers") else [cache]
    sizes = [1 << i for i in range(12)]
    for bag in bags:
        row0 = np.asarray(bag.pool)[:1]             # (1, D) flat slot 0
        for m in sizes:
            bag.hot.scatter(np.zeros(m, np.int64),
                            np.repeat(row0, m, axis=0))
    for m in sizes:                                 # remote fetch buckets
        bags[0].cold.fetch(np.zeros(m, np.int64), np.zeros(m, np.int64))


def _per_op_cost(fn, n: int = 20_000) -> float:
    t0 = time.perf_counter()
    for _ in range(n):
        fn()
    return (time.perf_counter() - t0) / n


def run(shape: dict, windows: dict, trace_path: str, metrics_path: str,
        csv_path: str | None, bench_path: str | None = None,
        smoke: bool = False) -> None:
    tel = Telemetry()
    tel.tracer.install_comm_sink()
    cfg = _config(shape)
    params = dlrm_mod.init_params(jax.random.key(0), cfg)
    rng = np.random.default_rng(1)
    B = max(*windows["train"], *windows["hold"])
    serial = make_dlrm_engine(params, cfg, batch_size=B, telemetry=tel)

    # warmup: compile every pad_pow2 fetch/scatter bucket and fill the
    # pool — these spans land on the timeline but sit BEFORE the train
    # mark, so the calibration windows never see compile time
    _prewarm_buckets(serial)
    wall = 0.0
    dt, rid0 = _serve(serial, cfg, windows["train"] + windows["hold"],
                      rng, 0, shape["zipf"])
    wall += dt

    mark_train = Tracer.now()
    dt, rid0 = _serve(serial, cfg, windows["train"], rng, rid0,
                      shape["zipf"])
    wall += dt
    train = tel.tracer.stage_samples(since=mark_train)

    mark_hold = Tracer.now()
    dt, rid0 = _serve(serial, cfg, windows["hold"], rng, rid0,
                      shape["zipf"])
    wall += dt
    hold = tel.tracer.stage_samples(since=mark_hold)

    # the pipelined engine shares the timeline: pipeline-lane stage
    # spans + its own request-latency histogram
    piped = make_dlrm_engine(params, _config(shape, depth=2),
                             batch_size=B, telemetry=tel)
    _prewarm_buckets(piped)
    dt, rid0 = _serve(piped, cfg, (B,) * windows["piped"], rng, rid0,
                      shape["zipf"])
    wall += dt
    tel.tracer.remove_comm_sink()

    # -- latency quantiles --------------------------------------------------
    print(f"== LATENCY (enqueue->score, {rid0} requests) ==")
    for eng in (serial, piped):
        h = tel.request_latency(eng.obs_name)
        assert h.count > 0, f"no latency observations for {eng.obs_name}"
        print(f"  {eng.obs_name:16s} n={h.count:4d}  "
              f"p50={h.p50 * 1e3:8.3f} ms  p95={h.p95 * 1e3:8.3f} ms  "
              f"p99={h.p99 * 1e3:8.3f} ms")

    # -- merged trace -------------------------------------------------------
    tel.export_trace(trace_path)
    with open(trace_path) as f:
        obj = json.load(f)
    n_events = validate_chrome_trace(obj)
    engines_seen = {e["args"]["engine"] for e in obj["traceEvents"]
                    if e.get("args", {}).get("engine")}
    assert {"dlrm", "dlrm_pipelined"} <= engines_seen, engines_seen
    comm_spans = [s for s in tel.tracer.spans(lane="comm",
                                              name="fetch_rows")
                  if s.seconds > 0]
    assert comm_spans, "no runtime-timestamped fetch_rows event on the trace"
    print(f"== TRACE ==\n  {trace_path}: {n_events} events, engines "
          f"{sorted(engines_seen)}, {len(comm_spans)} timed fetch_rows "
          f"collectives")

    # -- calibration: train window in, held-out window judged ---------------
    stages = sorted({s.stage for s in train})
    assert {"h2d", "fetch_remote"} <= set(stages), stages
    rep = SweepReport("sweep", "base", "window", "stage", "err_before",
                      "err_after")
    print(f"== CALIBRATION ({len(train)} train / {len(hold)} held-out "
          f"samples) ==")
    extra = {"calibration": {}}
    for base in (H100_DGX, TPU_V5E):
        res = calibrate(train, base)
        before = stage_time_error(hold, base)
        after = res.error(hold)
        print(f"  base {base.name}: fitted gather_overhead_s="
              f"{res.hw.gather_overhead_s:.2e} host_Bps="
              f"{res.hw.host_Bps:.2e} alpha_s={res.hw.bulk.alpha_s:.2e} "
              f"beta_Bps={res.hw.bulk.beta_Bps:.2e}")
        for stage in [*stages, "total"]:
            print(f"    held-out {stage:12s} rel err "
                  f"{before[stage]:8.4f} -> {after[stage]:8.4f}")
            rep.add(sweep="obs", base=base.name, window="holdout",
                    stage=stage, err_before=f"{before[stage]:.4f}",
                    err_after=f"{after[stage]:.4f}")
        assert after["total"] < before["total"], (
            f"calibration did not beat hand-set {base.name} constants on "
            f"the held-out window: {after['total']:.4f} >= "
            f"{before['total']:.4f}")
        extra["calibration"][base.name] = {
            "gather_overhead_s": res.hw.gather_overhead_s,
            "host_Bps": res.hw.host_Bps,
            "alpha_s": res.hw.bulk.alpha_s,
            "beta_Bps": res.hw.bulk.beta_Bps,
            "n_h2d": res.n_h2d, "n_remote": res.n_remote,
            "holdout_err_before": before, "holdout_err_after": after,
        }
        print(f"  OK: calibrated {base.name} beats hand-set constants "
              f"({after['total']:.4f} < {before['total']:.4f})")

    # -- overhead bound -----------------------------------------------------
    # projected from microbenchmarked per-op costs x actual counts — a
    # wall-clock A/B on a noisy CI host would drown the signal
    bench_tracer = Tracer()
    span_cost = _per_op_cost(
        lambda: bench_tracer.add_span("x", 0.0, 1.0, lane="engine"))
    bench_hist = Histogram("x")
    obs_cost = _per_op_cost(lambda: bench_hist.observe(1e-3))
    overhead = (span_cost * tel.tracer.event_count
                + obs_cost * tel.metrics.observation_count)
    frac = overhead / wall
    print(f"== OVERHEAD ==\n  {tel.tracer.event_count} spans x "
          f"{span_cost * 1e6:.2f} us + {tel.metrics.observation_count} "
          f"observations x {obs_cost * 1e6:.2f} us = {overhead * 1e3:.2f} "
          f"ms over {wall:.2f} s serving ({frac * 100:.3f}%)")
    assert frac < 0.02, f"telemetry overhead {frac:.4f} >= 2%"

    extra["overhead_fraction"] = frac
    extra["trace_events"] = n_events
    write_snapshot(metrics_path, metrics=tel.metrics, extra=extra)
    print(f"wrote {metrics_path}")
    if csv_path:
        rep.write(csv_path)
        print(f"wrote {csv_path}")
    if bench_path:
        # span/observation counts are pure functions of the serving
        # shapes; the calibration fit and overhead projection are
        # wall-clock-shaped, so they ride along as informational
        h100 = extra["calibration"][H100_DGX.name]
        record = make_bench_record(
            "obs", config=dict(shape, smoke=smoke, **windows),
            metrics={
                "trace_events": make_metric(
                    n_events, "1", "higher_is_better", 0.10),
                "observations": make_metric(
                    tel.metrics.observation_count, "1",
                    "higher_is_better", 0.10),
                "overhead_fraction": make_metric(
                    frac, "1", "lower_is_better", None),
                "calib_holdout_err_h100": make_metric(
                    h100["holdout_err_after"]["total"], "1",
                    "lower_is_better", None),
            })
        write_bench(bench_path, record)
        print(f"wrote {bench_path}")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="CI shapes: fewer serving windows")
    ap.add_argument("--trace", type=str, default="obs_trace.json")
    ap.add_argument("--metrics", type=str, default="obs_metrics.json",
                    help="write_snapshot JSON (full registry + calibration)")
    ap.add_argument("--csv", type=str, default=None)
    ap.add_argument("--bench", type=str, default="BENCH_obs.json",
                    help="BenchRecord output ('' to skip)")
    args = ap.parse_args()
    run(SHAPE, SMOKE if args.smoke else FULL, args.trace, args.metrics,
        args.csv, bench_path=args.bench or None, smoke=args.smoke)


if __name__ == "__main__":
    main()

"""End-to-end training driver: train a ~100M-param granite-family model for
a few hundred steps with the full production stack (RW-sharded vocab
embedding, AdamW + int8 moments, remat, checkpoints, deterministic data).

    PYTHONPATH=src python examples/lm_train.py --steps 300

On a 1-device host this runs unsharded; with more devices (or on a TPU
slice) pass nothing extra — the launcher builds the mesh automatically.
~100M params: 12L x d=768 x ff=3072, vocab 32768.
"""
import argparse
import dataclasses
import tempfile

from repro.configs.base import ModelConfig, TrainConfig
from repro.data import Prefetcher, lm_batches
from repro.train.loop import Trainer


def make_100m() -> ModelConfig:
    return ModelConfig(
        name="granite-100m", family="dense", num_layers=12, d_model=768,
        num_heads=12, num_kv_heads=4, d_ff=3072, vocab_size=32768,
        activation="silu", dtype="float32")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--ckpt-dir", default=None)
    args = ap.parse_args()

    cfg = make_100m()
    n_params = cfg.param_count()
    print(f"model: {cfg.name}  ~{n_params/1e6:.0f}M params")
    tc = TrainConfig(learning_rate=6e-4, warmup_steps=20,
                     total_steps=args.steps, checkpoint_every=100,
                     optimizer_state_dtype="int8")
    ckpt_dir = args.ckpt_dir or tempfile.mkdtemp(prefix="lm100m_")
    data = Prefetcher(lm_batches(cfg, args.batch, args.seq, seed=0))
    trainer = Trainer(cfg, tc, data, ckpt_dir=ckpt_dir)

    def log(step, m):
        if step % 10 == 0 or step <= 3:
            print(f"step {step:4d}  loss {m['loss']:.4f}  "
                  f"lr {m['lr']:.2e}  gnorm {m['grad_norm']:.2f}  "
                  f"{m['step_time_s']*1e3:.0f} ms")

    trainer.run(args.steps, on_metrics=log)
    data.close()
    losses = [m["loss"] for _, m in trainer.metrics_log]
    print(f"loss: {losses[0]:.3f} -> {losses[-1]:.3f} over "
          f"{len(losses)} steps; checkpoints in {ckpt_dir}")
    assert losses[-1] < losses[0]


if __name__ == "__main__":
    main()

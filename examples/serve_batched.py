"""Batched serving: LM continuous batching AND pipelined DLRM scoring.

LM cell: requests arrive, slots are admitted/evicted, one jitted
decode_step advances every active sequence.

DLRM cell: the same CTR request stream served by the serialized
``DLRMEngine`` (depth 1) and the ``PipelinedDLRMEngine`` (depth 2 —
double-buffered slot pools, shadow prefetch under the live forward),
configured PURELY through ``DLRMConfig`` fields; scores are asserted
equal and the measured stage spans / overlap fraction are printed.

    PYTHONPATH=src python examples/serve_batched.py
"""
import dataclasses
import time

import numpy as np
import jax

from repro import configs
from repro.models import lm
from repro.serving.engine import ContinuousBatcher, Request


def serve_dlrm_pipelined():
    """Depth-2 pipelined CTR scoring vs the serialized engine."""
    from repro.cache import CacheConfig
    from repro.configs import dlrm as dlrm_cfg
    from repro.models import dlrm as dlrm_mod
    from repro.obs import Telemetry
    from repro.obs.slo import SLOMonitor, SLOPolicy
    from repro.serving.engine import CTRRequest, make_dlrm_engine

    base = dataclasses.replace(
        dlrm_cfg.smoke(), kernel_mode="reference",
        cache=CacheConfig(rows=32, policy="lru"))
    params = dlrm_mod.init_params(jax.random.key(0), base)
    T, L, F = (base.num_sparse_features, base.pooling,
               base.num_dense_features)
    rng = np.random.default_rng(0)
    reqs = []
    for rid in range(24):
        ranks = rng.zipf(1.2, size=(T, L))
        reqs.append(CTRRequest(
            rid=rid, dense=rng.standard_normal(F).astype(np.float32),
            indices=np.minimum(ranks - 1,
                               base.rows_per_table - 1).astype(np.int32),
            lengths=rng.integers(1, L + 1, T).astype(np.int32)))

    # engine selection is pure config: cache.pipeline_depth 1 vs 2;
    # one Telemetry watches both, and an SLOMonitor evaluates the
    # pipelined engine's windows as they complete (a tick listener)
    tel = Telemetry(window=4)
    serial = make_dlrm_engine(params, base, batch_size=8, telemetry=tel)
    piped = make_dlrm_engine(
        params,
        dataclasses.replace(
            base, cache=dataclasses.replace(base.cache, pipeline_depth=2)),
        batch_size=8, telemetry=tel)
    # a generous latency budget (smoke run, includes jit compiles) plus
    # a hit-rate floor the COLD-START windows are expected to breach —
    # demonstrating the monitor actually fires
    mon = SLOMonitor(tel, SLOPolicy(
        name="example", p99_budget_s=30.0, hit_rate_floor=0.05,
        queue_depth_cap=256), engine=piped.obs_name)
    for r in reqs:
        serial.submit(r)
        piped.submit(r)
    want = serial.run_to_completion()
    got = piped.run_to_completion()
    assert sorted(got) == sorted(want)
    assert all(got[rid] == want[rid] for rid in want), \
        "pipelined scores must equal the serialized engine's"
    s, ss = piped.cache_stats(), serial.cache_stats()
    print(f"DLRM: {len(reqs)} reqs x 2 engines, scores equal "
          f"(depth 2 vs depth 1)")
    print(f"  serialized spans: prefetch={ss.prefetch_s*1e3:.1f}ms "
          f"scatter={ss.scatter_s*1e3:.1f}ms forward={ss.forward_s*1e3:.1f}ms"
          f" (overlap {ss.overlap_fraction:.2f})")
    print(f"  pipelined  spans: prefetch={s.prefetch_s*1e3:.1f}ms "
          f"scatter={s.scatter_s*1e3:.1f}ms forward={s.forward_s*1e3:.1f}ms "
          f"(overlap {s.overlap_fraction:.2f})")
    for stage in ("admit", "fetch", "scatter", "forward", "swap"):
        print(f"    stage {stage:8s} {piped.trace.total(stage)*1e3:8.2f}ms")
    # end-of-run SLO summary: every completed window was judged live
    summ = mon.summary()
    print(f"  SLO [{summ['policy']}] windows={summ['windows_evaluated']} "
          f"breaches={summ['breaches']} "
          f"worst_p99={summ['worst_p99_s']*1e3:.2f}ms "
          f"by_rule={summ['breaches_by_rule']}")
    assert summ["windows_evaluated"] > 0, "the monitor must see windows"
    # cold-start hit_rate breaches are expected; latency/depth are not
    assert set(summ["breaches_by_rule"]) <= {"hit_rate"}, \
        "a 30s p99 budget / 256-deep queue cap must not breach here"


def main():
    cfg = configs.get_smoke_config("granite-8b")
    params = lm.init_params(jax.random.key(0), cfg)
    eng = ContinuousBatcher(params, cfg, num_slots=4, max_len=64, eos_id=-1)

    rng = np.random.default_rng(0)
    n_req = 10
    for rid in range(n_req):
        eng.submit(Request(
            rid=rid,
            prompt=rng.integers(0, cfg.vocab_size,
                                rng.integers(4, 12)).astype(np.int32),
            max_new=int(rng.integers(4, 10))))

    t0 = time.perf_counter()
    done = eng.run_to_completion()
    dt = time.perf_counter() - t0
    total = sum(len(r.generated) for r in done.values())
    print(f"served {len(done)}/{n_req} requests, {total} tokens in "
          f"{dt:.2f}s ({total/dt:.1f} tok/s, 4 slots, continuous batching)")
    for rid in sorted(done):
        r = done[rid]
        print(f"  req {rid}: prompt_len={len(r.prompt)} -> "
              f"{len(r.generated)} tokens: {r.generated}")
    assert len(done) == n_req

    serve_dlrm_pipelined()


if __name__ == "__main__":
    main()

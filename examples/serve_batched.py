"""Batched serving with continuous batching: requests arrive, slots are
admitted/evicted, one jitted decode_step advances every active sequence.

    PYTHONPATH=src python examples/serve_batched.py
"""
import time

import numpy as np
import jax

from repro import configs
from repro.models import lm
from repro.serving.engine import ContinuousBatcher, Request


def main():
    cfg = configs.get_smoke_config("granite-8b")
    params = lm.init_params(jax.random.key(0), cfg)
    eng = ContinuousBatcher(params, cfg, num_slots=4, max_len=64, eos_id=-1)

    rng = np.random.default_rng(0)
    n_req = 10
    for rid in range(n_req):
        eng.submit(Request(
            rid=rid,
            prompt=rng.integers(0, cfg.vocab_size,
                                rng.integers(4, 12)).astype(np.int32),
            max_new=int(rng.integers(4, 10))))

    t0 = time.perf_counter()
    done = eng.run_to_completion()
    dt = time.perf_counter() - t0
    total = sum(len(r.generated) for r in done.values())
    print(f"served {len(done)}/{n_req} requests, {total} tokens in "
          f"{dt:.2f}s ({total/dt:.1f} tok/s, 4 slots, continuous batching)")
    for rid in sorted(done):
        r = done[rid]
        print(f"  req {rid}: prompt_len={len(r.prompt)} -> "
              f"{len(r.generated)} tokens: {r.generated}")
    assert len(done) == n_req


if __name__ == "__main__":
    main()

"""End-to-end DLRM inference — the paper's model (Fig. 2) with the
distributed Embedding Bag under every sharding strategy AND the tiered
embedding store serving path.

    PYTHONPATH=src python examples/dlrm_inference.py

Single device: serves batched CTR requests through ``DLRMEngine`` with
the tiered cache configured ENTIRELY through ``DLRMConfig.cache`` (one
``CacheConfig`` carrying rows / policy / cold_tier / warmup_freqs) —
the engine's HBM holds only the flat slot pool, the cold tables stay
host-resident — and cross-checks the scores against the uncached
direct forward.

With >1 devices (XLA_FLAGS=--xla_force_host_platform_device_count=8) it
additionally compares all distributed sharding strategies (RW both
impls / CW / TW) for correctness and traces the collective traffic each
one issues (the paper's phase structure).
"""
import dataclasses
import time

import numpy as np
import jax
import jax.numpy as jnp

from repro.cache import CacheConfig
from repro.configs import dlrm as dlrm_cfg
from repro.core import comm
from repro.core.jagged import JaggedBatch, random_jagged_batch
from repro.core.parallel import make_context
from repro.models import dlrm as dlrm_mod
from repro.serving.engine import CTRRequest, DLRMEngine


def serve_tiered(base, params, rng):
    """DLRMEngine over the tiered store, configured via DLRMConfig only."""
    T, L, F = (base.num_sparse_features, base.pooling,
               base.num_dense_features)
    # logged frequencies (the offline ids_freq_mapping): zipf-ish skew
    freqs = (1.0 / np.arange(1, base.rows_per_table + 1)) * 1e4
    cfg = dataclasses.replace(
        base,
        cache=CacheConfig(
            rows=max(base.pooling, base.rows_per_table // 8),
            policy="lfu",
            cold_tier="host",        # "remote" once >1 hosts back the store
            warmup_freqs=freqs,      # skip the cold-start miss burst
        ),
    )
    engine = DLRMEngine(params, cfg, batch_size=8)
    assert engine.params["tables"] is None, "HBM must hold only the pool"

    reqs = []
    for rid in range(24):
        ranks = rng.zipf(1.2, size=(T, L))
        reqs.append(CTRRequest(
            rid=rid,
            dense=rng.standard_normal(F).astype(np.float32),
            indices=np.minimum(ranks - 1,
                               base.rows_per_table - 1).astype(np.int32),
            lengths=rng.integers(1, L + 1, T).astype(np.int32)))
        engine.submit(reqs[-1])
    scores = engine.run_to_completion()

    worst = 0.0
    for r in reqs:
        batch = JaggedBatch(jnp.asarray(r.indices[:, None, :]),
                            jnp.asarray(r.lengths[:, None]))
        want = float(jax.nn.sigmoid(dlrm_mod.forward(
            params, jnp.asarray(r.dense[None]), batch, base))[0])
        worst = max(worst, abs(scores[r.rid] - want))
    stats = engine.cache_stats()
    print(f"tiered engine: {len(reqs)} reqs scored, max |err| vs uncached "
          f"forward = {worst:.2e}")
    print(f"tiered engine: {stats}")
    assert worst < 1e-5
    # warmup pre-admitted the logged-hot head: the FIRST flush already hits
    assert stats.hits > 0 and stats.hit_rate > 0.5, str(stats)


def main():
    n_dev = len(jax.devices())
    base = dataclasses.replace(
        dlrm_cfg.smoke(), num_sparse_features=8, rows_per_table=4096,
        embedding_dim=64, pooling=16, bottom_mlp=(128, 64))
    B = 32
    rng = np.random.default_rng(0)
    dense = jnp.asarray(rng.standard_normal((B, base.num_dense_features)),
                        jnp.float32)
    batch = random_jagged_batch(rng, base.num_sparse_features, B,
                                base.pooling, base.rows_per_table)
    params = dlrm_mod.init_params(jax.random.key(0), base)

    ref = dlrm_mod.forward(params, dense, batch, base)
    print(f"local oracle CTR logits[:4] = {np.asarray(ref[:4]).round(4)}")

    serve_tiered(base, params, rng)

    if n_dev == 1:
        print("single device: distributed comparison needs >1 device "
              "(run under XLA_FLAGS=--xla_force_host_platform_device_count=8)")
        return

    mesh = jax.make_mesh((1, n_dev), ("data", "model"))
    ctx = make_context(mesh)
    for sharding, impl in [("row", "allgather"), ("row", "a2a"),
                           ("column", None), ("table", None)]:
        cfg = dataclasses.replace(base, sharding=sharding,
                                  rw_impl=impl or "allgather")
        with comm.instrument() as events:
            out = jax.jit(lambda p, d, b: dlrm_mod.forward(
                p, d, b, cfg, ctx))(params, dense, batch)
        err = float(jnp.abs(out - ref).max())
        traffic = {}
        for e in events:
            traffic[e.op] = traffic.get(e.op, 0) + e.bytes_in
        t0 = time.perf_counter()
        jax.jit(lambda p, d, b: dlrm_mod.forward(p, d, b, cfg, ctx))(
            params, dense, batch).block_until_ready()
        dt = time.perf_counter() - t0
        print(f"{sharding:7s}{('/' + impl) if impl else '':11s} "
              f"err={err:.1e}  traffic={traffic}  ({dt*1e3:.0f} ms incl. "
              f"compile)")
    print("OK: every sharding strategy reproduces the oracle.")


if __name__ == "__main__":
    main()

"""End-to-end DLRM inference — the paper's model (Fig. 2) with the
distributed Embedding Bag under every sharding strategy.

    PYTHONPATH=src python examples/dlrm_inference.py

Serves batched CTR requests through bottom-MLP -> RW-sharded embedding
pooling -> dot interaction -> top-MLP, comparing all sharding strategies
(RW both impls / CW / TW / replicated) for correctness and tracing the
collective traffic each one issues (the paper's phase structure).
"""
import dataclasses
import time

import numpy as np
import jax
import jax.numpy as jnp

from repro.configs import dlrm as dlrm_cfg
from repro.core import comm
from repro.core.jagged import random_jagged_batch
from repro.core.parallel import make_context
from repro.models import dlrm as dlrm_mod


def main():
    n_dev = len(jax.devices())
    base = dataclasses.replace(
        dlrm_cfg.smoke(), num_sparse_features=8, rows_per_table=4096,
        embedding_dim=64, pooling=16, bottom_mlp=(128, 64))
    B = 32
    rng = np.random.default_rng(0)
    dense = jnp.asarray(rng.standard_normal((B, base.num_dense_features)),
                        jnp.float32)
    batch = random_jagged_batch(rng, base.num_sparse_features, B,
                                base.pooling, base.rows_per_table)
    params = dlrm_mod.init_params(jax.random.key(0), base)

    ref = dlrm_mod.forward(params, dense, batch, base)
    print(f"local oracle CTR logits[:4] = {np.asarray(ref[:4]).round(4)}")

    if n_dev == 1:
        print("single device: distributed comparison needs >1 device "
              "(run under XLA_FLAGS=--xla_force_host_platform_device_count=8)")
        return

    mesh = jax.make_mesh((1, n_dev), ("data", "model"))
    ctx = make_context(mesh)
    for sharding, impl in [("row", "allgather"), ("row", "a2a"),
                           ("column", None), ("table", None)]:
        cfg = dataclasses.replace(base, sharding=sharding,
                                  rw_impl=impl or "allgather")
        with comm.instrument() as events:
            out = jax.jit(lambda p, d, b: dlrm_mod.forward(
                p, d, b, cfg, ctx))(params, dense, batch)
        err = float(jnp.abs(out - ref).max())
        traffic = {}
        for e in events:
            traffic[e.op] = traffic.get(e.op, 0) + e.bytes_in
        t0 = time.perf_counter()
        jax.jit(lambda p, d, b: dlrm_mod.forward(p, d, b, cfg, ctx))(
            params, dense, batch).block_until_ready()
        dt = time.perf_counter() - t0
        print(f"{sharding:7s}{('/' + impl) if impl else '':11s} "
              f"err={err:.1e}  traffic={traffic}  ({dt*1e3:.0f} ms incl. "
              f"compile)")
    print("OK: every sharding strategy reproduces the oracle.")


if __name__ == "__main__":
    main()

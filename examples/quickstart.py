"""Quickstart: the paper's distributed Embedding Bag in 60 lines.

    PYTHONPATH=src python examples/quickstart.py

Builds a row-wise-sharded embedding bag on a (1, N)-device mesh (uses all
local devices), runs the paper's three-phase pipeline (index permute ->
gather/pool -> reduce-scatter), and verifies it against the local oracle.
"""
import numpy as np
import jax
import jax.numpy as jnp
from repro.utils.compat import shard_map
from jax.sharding import PartitionSpec as P

from repro.core.embedding_bag import (
    EmbeddingBagConfig,
    init_tables,
    pooled_lookup_local,
    pooled_lookup_sharded,
    table_pspec,
)
from repro.core.jagged import random_jagged_batch


def main():
    n_dev = len(jax.devices())
    mesh = jax.make_mesh((n_dev,), ("model",))
    print(f"mesh: {n_dev} devices on axis 'model'")

    cfg = EmbeddingBagConfig(
        num_tables=8,             # 8 sparse features
        rows_per_table=1 << 16,   # 65k rows each
        dim=128,                  # paper fixes dim=128
        sharding="row",           # the paper's RW parallelism
        rw_impl="a2a",            # paper-faithful 3-phase pipeline
        capacity_factor=4.0,
    )
    tables = init_tables(jax.random.key(0), cfg)
    print(f"tables: {tables.shape} = "
          f"{tables.size * 4 / 2**20:.0f} MiB, row-sharded {n_dev}-way")

    rng = np.random.default_rng(0)
    batch = random_jagged_batch(
        rng, cfg.num_tables, batch_size=64, pooling=16,
        num_rows=cfg.rows_per_table)

    pooled = jax.jit(shard_map(
        lambda t, b: pooled_lookup_sharded(t, b, cfg),
        mesh=mesh,
        in_specs=(table_pspec(cfg), P()),
        out_specs=P(),
        check_vma=False,
    ))(tables, batch)
    print(f"pooled output: {pooled.shape}  (batch, tables, dim)")

    ref = pooled_lookup_local(tables, batch, cfg)
    err = float(jnp.abs(pooled - ref).max())
    print(f"max |distributed - local oracle| = {err:.2e}")
    assert err < 1e-4
    print("OK: the distributed pipeline reproduces the local pooling.")


if __name__ == "__main__":
    main()

"""Cost-model-driven sharding plan for a heterogeneous DLRM table set.

    PYTHONPATH=src python examples/dlrm_sharding_plan.py

The paper fixes row-wise parallelism and equal table sizes (§4.3); real
Criteo-scale models mix 10-row enum tables with 100M-row id tables. The
planner (core/sharding_plan.py — a small deterministic AutoShard) packs
small tables table-wise onto the least-loaded shard and row-splits the
giants, minimizing modeled step time under the per-chip HBM budget.
"""
import numpy as np

from repro.core.perf_model import TPU_V5E
from repro.core.sharding_plan import TableSpec, plan


def criteo_like_tables(seed=0):
    """26 sparse features with a realistic (log-uniform) size spread."""
    rng = np.random.default_rng(seed)
    rows = np.unique(np.concatenate([
        10 ** rng.uniform(1, 8, size=22),       # enums .. big id spaces
        [4e7, 1e8, 2e8, 3e8],                   # the Criteo giants
    ]).astype(np.int64))[:26]
    return [TableSpec(f"sparse_{i:02d}", rows=int(r), dim=128, pooling=32)
            for i, r in enumerate(sorted(rows, key=int))]


def main():
    tables = criteo_like_tables()
    total = sum(t.bytes for t in tables)
    # ~376 GB of fp32 tables: the paper's own sizing rule (§5.2,
    # table_bytes / per-chip budget) demands ~64 v5e chips for embeddings
    shards = 64
    budget = 8e9                                 # 8 GB of the 16 GB chip
    print(f"{len(tables)} tables, {total/1e9:.1f} GB total, "
          f"{shards} shards, {budget/1e9:.0f} GB/shard embedding budget\n")
    for batch in (1024, 32):
        p = plan(tables, num_shards=shards, batch_per_shard=batch,
                 hbm_budget_bytes=budget, hw=TPU_V5E)
        n_tw = sum(1 for x in p.placements if x.strategy == "table")
        n_rw = sum(1 for x in p.placements if x.strategy == "row")
        print(f"batch/shard={batch}: {n_tw} table-wise, {n_rw} row-wise; "
              f"max shard {max(p.per_shard_bytes)/1e9:.2f} GB")
        for x in sorted(p.placements, key=lambda x: -x.table.bytes)[:4]:
            print(f"    {x.table.name}: {x.table.rows:>12,} rows "
                  f"({x.table.bytes/1e9:6.2f} GB) -> {x.strategy:5s} "
                  f"(modeled {x.est_time_s*1e6:7.1f} us)")
        assert max(p.per_shard_bytes) <= budget * 1.25
        print()
    print("OK: giants are always row-split (the paper's regime); at small "
          "batch the collective latency floor makes table-wise placement "
          "win for the small tables — the Fig. 1 crossover, reappearing "
          "as a placement decision.")


if __name__ == "__main__":
    main()
